"""Kernel-parity suite: every Pallas kernel (rgcn_spmm dense + flat-edge,
rgcn_fused, kmeans_assign, flash_attention, ssd_scan) against its pure-jnp
`ref.py` oracle in interpret mode, across odd / non-power-of-two shapes,
empty-edge and single-node degenerate cases, and f32/bf16 dtypes.

Complements tests/test_kernels.py (which pins the happy-path shapes); this
file owns the shape/dtype boundary grid so kernel edits can't silently
regress a case the standard shapes never exercise.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.kernel import flash_attention_fwd
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.kmeans_assign.ops import (
    kmeans_assign, kmeans_assign_fused, silhouette_sums,
)
from repro.kernels.kmeans_assign.ref import (
    kmeans_assign_fused_ref, kmeans_assign_ref, silhouette_sums_ref,
)
from repro.kernels.rgcn_fused.ops import (
    fused_two_level_readout, rgcn_fused_agg_flat,
)
from repro.kernels.rgcn_fused.ref import (
    rgcn_fused_agg_flat_ref, two_level_readout_ref,
)
from repro.kernels.rgcn_spmm.ops import rgcn_message_agg, rgcn_message_agg_flat
from repro.kernels.rgcn_spmm.ref import (
    rgcn_message_agg_flat_ref, rgcn_message_agg_ref,
)
from repro.kernels.ssd_scan.ops import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_sequential_ref

F32, BF16 = jnp.float32, jnp.bfloat16


def _tol(dtype):
    return 1e-4 if dtype == F32 else 3e-2


def _close(a, b, tol):
    np.testing.assert_allclose(
        np.asarray(a, np.float32), np.asarray(b, np.float32),
        atol=tol, rtol=tol,
    )


# ---------------------------------------------------------------------------
# rgcn_spmm — flat (packed-batch) variant
# ---------------------------------------------------------------------------

RGCN_FLAT_SHAPES = [
    # (P, D, Q, nb, O) — odd / non-pow2 node+edge counts, Q < block_e,
    # Q straddling a block boundary
    (33, 8, 7, 2, 8),
    (100, 16, 257, 3, 24),
    (1, 4, 3, 2, 6),       # single node, self-loops only
    (65, 8, 256, 2, 8),    # Q exactly one block
]


@pytest.mark.parametrize("P,D,Q,nb,O", RGCN_FLAT_SHAPES)
@pytest.mark.parametrize("dtype", [F32, BF16])
def test_rgcn_flat_parity(P, D, Q, nb, O, dtype):
    ks = jax.random.split(jax.random.PRNGKey(10), 5)
    h = jax.random.normal(ks[0], (P, D), dtype)
    basis = jax.random.normal(ks[1], (nb, D, O), dtype)
    src = jax.random.randint(ks[2], (Q,), 0, P)
    dst = jax.random.randint(ks[3], (Q,), 0, P)
    w = jax.random.normal(ks[4], (Q, nb), dtype)
    out = rgcn_message_agg_flat(h, basis, src, dst, w, P, True)
    ref = rgcn_message_agg_flat_ref(
        h.astype(F32), basis.astype(F32), src, dst, w.astype(F32), P)
    _close(out, ref, _tol(dtype))


def test_rgcn_flat_empty_edges():
    """Q = 0: the aggregation is identically zero (no division-by-zero in
    the block padding)."""
    h = jax.random.normal(jax.random.PRNGKey(0), (8, 4))
    basis = jax.random.normal(jax.random.PRNGKey(1), (2, 4, 6))
    e = jnp.zeros((0,), jnp.int32)
    out = rgcn_message_agg_flat(h, basis, e, e, jnp.zeros((0, 2)), 8, True)
    assert out.shape == (8, 6)
    _close(out, jnp.zeros((8, 6)), 1e-6)


def test_rgcn_flat_masked_edges_are_noops():
    """w = 0 rows (padding edges in the packed batch) contribute nothing —
    the invariant the edge-bucket padding in core/batching.py relies on."""
    ks = jax.random.split(jax.random.PRNGKey(11), 5)
    P, D, nb, O = 16, 8, 2, 8
    h = jax.random.normal(ks[0], (P, D))
    basis = jax.random.normal(ks[1], (nb, D, O))
    src = jax.random.randint(ks[2], (20,), 0, P)
    dst = jax.random.randint(ks[3], (20,), 0, P)
    w = jax.random.normal(ks[4], (20, nb))
    base = rgcn_message_agg_flat(h, basis, src, dst, w, P, True)
    srcp = jnp.concatenate([src, jnp.zeros(13, jnp.int32)])
    dstp = jnp.concatenate([dst, jnp.zeros(13, jnp.int32)])
    wp = jnp.concatenate([w, jnp.zeros((13, nb))])
    padded = rgcn_message_agg_flat(h, basis, srcp, dstp, wp, P, True)
    _close(base, padded, 1e-5)


RGCN_DENSE_SHAPES = [
    # (B, N, D, E, nb, O)
    (1, 33, 8, 7, 2, 8),
    (2, 1, 4, 3, 2, 6),    # single node per graph
    (3, 17, 8, 130, 2, 12),
]


@pytest.mark.parametrize("B,N,D,E,nb,O", RGCN_DENSE_SHAPES)
@pytest.mark.parametrize("dtype", [F32, BF16])
def test_rgcn_dense_parity(B, N, D, E, nb, O, dtype):
    ks = jax.random.split(jax.random.PRNGKey(12), 5)
    h = jax.random.normal(ks[0], (B, N, D), dtype)
    basis = jax.random.normal(ks[1], (nb, D, O), dtype)
    src = jax.random.randint(ks[2], (B, E), 0, N)
    dst = jax.random.randint(ks[3], (B, E), 0, N)
    w = jax.random.normal(ks[4], (B, E, nb), dtype)
    out = rgcn_message_agg(h, basis, src, dst, w, N, True)
    ref = rgcn_message_agg_ref(
        h.astype(F32), basis.astype(F32), src, dst, w.astype(F32), N)
    _close(out, ref, _tol(dtype))


def test_rgcn_dense_empty_edges():
    h = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 4))
    basis = jax.random.normal(jax.random.PRNGKey(1), (2, 4, 6))
    e = jnp.zeros((2, 0), jnp.int32)
    out = rgcn_message_agg(h, basis, e, e, jnp.zeros((2, 0, 2)), 8, True)
    assert out.shape == (2, 8, 6)
    _close(out, jnp.zeros((2, 8, 6)), 1e-6)


# ---------------------------------------------------------------------------
# rgcn_fused — one-pass message+norm+scatter+basis layer (DESIGN.md §12)
# ---------------------------------------------------------------------------


def _fused_inputs(key, P, D, Q, nb, O, dtype):
    ks = jax.random.split(key, 6)
    h = jax.random.normal(ks[0], (P, D), dtype)
    basis = jax.random.normal(ks[1], (nb, D, O), dtype)
    src = jax.random.randint(ks[2], (Q,), 0, P)
    dst = jax.random.randint(ks[3], (Q,), 0, P)
    coef = jax.random.normal(ks[4], (Q, nb), dtype)
    # wnorm mimics edge_mask * edge_norm: zeros (masked padding) and (0,1]
    wnorm = jax.random.uniform(ks[5], (Q,), jnp.float32)
    wnorm = jnp.where(wnorm < 0.25, 0.0, wnorm)
    return h, basis, src, dst, coef, wnorm


@pytest.mark.parametrize("P,D,Q,nb,O", RGCN_FLAT_SHAPES)
@pytest.mark.parametrize("dtype", [F32, BF16])
def test_rgcn_fused_flat_parity(P, D, Q, nb, O, dtype):
    h, basis, src, dst, coef, wnorm = _fused_inputs(
        jax.random.PRNGKey(20), P, D, Q, nb, O, dtype)
    out = rgcn_fused_agg_flat(h, basis, src, dst, coef, wnorm, P, True)
    ref = rgcn_fused_agg_flat_ref(
        h.astype(F32), basis.astype(F32), src, dst,
        coef.astype(F32), wnorm, P)
    _close(out, ref, _tol(dtype))


def test_rgcn_fused_matches_unfused_triple():
    """The fused kernel reproduces the rgcn_spmm path it replaces:
    agg == rgcn_message_agg_flat(h, basis, src, dst, coef * wnorm)."""
    P, D, Q, nb, O = 65, 8, 130, 2, 8
    h, basis, src, dst, coef, wnorm = _fused_inputs(
        jax.random.PRNGKey(21), P, D, Q, nb, O, F32)
    fused = rgcn_fused_agg_flat(h, basis, src, dst, coef, wnorm, P, True)
    unfused = rgcn_message_agg_flat(
        h, basis, src, dst, coef * wnorm[:, None], P, True)
    _close(fused, unfused, 1e-5)


def test_rgcn_fused_empty_edges():
    """Q = 0: identically zero, no division-by-zero in block padding."""
    h = jax.random.normal(jax.random.PRNGKey(0), (8, 4))
    basis = jax.random.normal(jax.random.PRNGKey(1), (2, 4, 6))
    e = jnp.zeros((0,), jnp.int32)
    out = rgcn_fused_agg_flat(h, basis, e, e, jnp.zeros((0, 2)),
                              jnp.zeros((0,)), 8, True)
    assert out.shape == (8, 6)
    _close(out, jnp.zeros((8, 6)), 1e-6)


def test_rgcn_fused_single_node():
    """P = 1 (self-loops only) survives the one-hot scatter."""
    h, basis, src, dst, coef, wnorm = _fused_inputs(
        jax.random.PRNGKey(22), 1, 4, 3, 2, 6, F32)
    out = rgcn_fused_agg_flat(h, basis, src, dst, coef, wnorm, 1, True)
    ref = rgcn_fused_agg_flat_ref(h, basis, src, dst, coef, wnorm, 1)
    _close(out, ref, _tol(F32))


def test_rgcn_fused_masked_edges_are_noops():
    """wnorm = 0 rows (padding edges) contribute nothing — the invariant
    the edge-bucket padding in core/batching.py relies on."""
    P, D, Q, nb, O = 16, 8, 20, 2, 8
    h, basis, src, dst, coef, wnorm = _fused_inputs(
        jax.random.PRNGKey(23), P, D, Q, nb, O, F32)
    base = rgcn_fused_agg_flat(h, basis, src, dst, coef, wnorm, P, True)
    pad = 13
    srcp = jnp.concatenate([src, jnp.zeros(pad, jnp.int32)])
    dstp = jnp.concatenate([dst, jnp.zeros(pad, jnp.int32)])
    coefp = jnp.concatenate([coef, jnp.ones((pad, nb))])  # nonzero coef,
    wnormp = jnp.concatenate([wnorm, jnp.zeros(pad)])     # zero wnorm
    padded = rgcn_fused_agg_flat(h, basis, srcp, dstp, coefp, wnormp, P, True)
    _close(base, padded, 1e-5)


@pytest.mark.parametrize("dtype", [F32, BF16])
def test_rgcn_fused_grads_match_ref(dtype):
    """fwd+bwd: custom_vjp backward (oracle vjp) vs differentiating the ref
    directly — checks the residual wiring and nondiff argnums."""
    P, D, Q, nb, O = 33, 8, 57, 2, 8
    h, basis, src, dst, coef, wnorm = _fused_inputs(
        jax.random.PRNGKey(24), P, D, Q, nb, O, dtype)
    cot = jax.random.normal(jax.random.PRNGKey(25), (P, O), F32)

    def loss_fused(h_, basis_, coef_, wnorm_):
        out = rgcn_fused_agg_flat(h_, basis_, src, dst, coef_, wnorm_,
                                  P, True)
        return jnp.sum(out * cot)

    def loss_ref(h_, basis_, coef_, wnorm_):
        out = rgcn_fused_agg_flat_ref(h_, basis_, src, dst, coef_, wnorm_, P)
        return jnp.sum(out * cot)

    gf = jax.grad(loss_fused, argnums=(0, 1, 2, 3))(h, basis, coef, wnorm)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2, 3))(h, basis, coef, wnorm)
    for a, b in zip(gf, gr):
        _close(a, b, _tol(dtype))


def test_fused_two_level_readout_bit_exact():
    """The concatenated sum|count readout is BIT-exact vs the four-sum
    epilogue — per-column segment sums are independent."""
    rng = np.random.default_rng(3)
    P, D, W, G = 37, 16, 9, 4
    h = jnp.asarray(rng.standard_normal((P, D)), jnp.float32)
    node_mask = jnp.asarray(rng.random(P) < 0.8, jnp.float32)
    warp_seg = jnp.asarray(rng.integers(0, W, P), jnp.int32)
    warp_graph = jnp.asarray(rng.integers(0, G, W), jnp.int32)
    fused = fused_two_level_readout(h, node_mask, warp_seg, warp_graph, G)
    ref = two_level_readout_ref(h, node_mask, warp_seg, warp_graph, W, G)
    assert np.array_equal(np.asarray(fused), np.asarray(ref))


def test_fused_two_level_readout_empty_warp():
    """A warp with zero live nodes stays out of the graph mean (valid=0)."""
    P, D, W, G = 8, 4, 3, 2
    h = jnp.ones((P, D), jnp.float32)
    node_mask = jnp.ones((P,), jnp.float32)
    warp_seg = jnp.zeros((P,), jnp.int32)      # warps 1, 2 empty
    warp_graph = jnp.asarray([0, 0, 1], jnp.int32)
    fused = fused_two_level_readout(h, node_mask, warp_seg, warp_graph, G)
    ref = two_level_readout_ref(h, node_mask, warp_seg, warp_graph, W, G)
    assert np.array_equal(np.asarray(fused), np.asarray(ref))
    assert np.array_equal(np.asarray(fused[1]), np.zeros(D, np.float32))


@pytest.mark.parametrize("seed", [0, 7])
def test_precomputed_edge_norm_matches_recompute(seed):
    """pack_graphs' hoisted numpy degree normalizer (schema v2) is BIT-exact
    vs the per-layer jnp recomputation it replaced (including padding rows,
    which both paths clamp to 1).  The hypothesis sweep over arbitrary
    packed batches lives in tests/test_batching_property.py."""
    from repro.core.batching import pack_graphs
    from repro.core.graphs import NUM_RELATIONS, build_kernel_graph
    from repro.core.rgcn import edge_norm_packed
    from repro.tracing.templates import make_kernel

    ks = [
        make_kernel(f"g{i}", "gemm",
                    {"M": 128 * (i + 1), "N": 128, "K": 128}, i,
                    seed=seed * 10 + i)
        for i in range(3)
    ]
    graphs = [build_kernel_graph(k.trace(cap_warps=2, cap_instr=24))
              for k in ks]
    packed, _ = pack_graphs(graphs)
    assert packed["edge_norm"].dtype == np.float32
    recomputed = edge_norm_packed(
        jnp.asarray(packed["edge_dst"]), jnp.asarray(packed["edge_type"]),
        jnp.asarray(packed["edge_mask"]), packed["node_mask"].shape[0],
        NUM_RELATIONS,
    )
    assert np.array_equal(np.asarray(recomputed), packed["edge_norm"])


# ---------------------------------------------------------------------------
# kmeans_assign
# ---------------------------------------------------------------------------

KMEANS_SHAPES = [
    # (n, d, k, block_n)
    (37, 19, 5, 16),       # odd everything, n % block != 0
    (1, 7, 3, 512),        # single point
    (9, 5, 1, 4),          # single centroid
    (513, 33, 7, 512),     # one past the block boundary
]


@pytest.mark.parametrize("n,d,k,block_n", KMEANS_SHAPES)
def test_kmeans_assign_parity(n, d, k, block_n):
    ks = jax.random.split(jax.random.PRNGKey(20), 2)
    x = jax.random.normal(ks[0], (n, d))
    cent = jax.random.normal(ks[1], (k, d))
    labels, dists = kmeans_assign(x, cent, block_n=block_n, interpret=True)
    ref_labels, ref_dists = kmeans_assign_ref(x, cent)
    np.testing.assert_array_equal(np.asarray(labels), np.asarray(ref_labels))
    _close(dists, ref_dists, 1e-4)
    assert labels.shape == (n,) and labels.dtype == jnp.int32


def test_kmeans_assign_bf16_separated():
    """bf16 inputs: argmin must stay exact when clusters are well separated
    (ties under low precision would be a real regression)."""
    rng = np.random.default_rng(0)
    k, d, per = 4, 16, 25
    cent = rng.normal(size=(k, d)).astype(np.float32) * 20.0
    x = np.concatenate([cent[i] + rng.normal(size=(per, d)).astype(np.float32)
                        for i in range(k)])
    want = np.repeat(np.arange(k), per)
    labels, _ = kmeans_assign(jnp.asarray(x, BF16), jnp.asarray(cent, BF16),
                              block_n=32, interpret=True)
    np.testing.assert_array_equal(np.asarray(labels), want)


@pytest.mark.parametrize("n,d,k,block_n,dead,pad", [
    (100, 16, 4, 32, 0, 0),
    (257, 24, 6, 128, 2, 17),    # masked centroid slots + padded points
    (64, 8, 3, 64, 1, 5),        # single block
    (7, 8, 5, 64, 0, 3),         # n < block, pad > live points per cluster
])
def test_kmeans_assign_fused_parity(n, d, k, block_n, dead, pad):
    """Fused assign + min-dist + per-cluster-sum (the swept Lloyd step):
    labels/dists/sums/counts against the oracle, with dead centroid slots
    and padded points masked out."""
    ks = jax.random.split(jax.random.PRNGKey(n + d), 2)
    x = jax.random.normal(ks[0], (n, d))
    cent = jax.random.normal(ks[1], (k, d))
    cmask = jnp.where(jnp.arange(k) < k - dead, 1.0, 0.0)
    pmask = jnp.where(jnp.arange(n) < n - pad, 1.0, 0.0)
    lab, dist, sums, cnts = kmeans_assign_fused(
        x, cent, cmask, pmask, block_n=block_n, interpret=True)
    rl, rd, rs, rc = kmeans_assign_fused_ref(x, cent, cmask, pmask)
    np.testing.assert_array_equal(np.asarray(lab), np.asarray(rl))
    _close(dist, rd, 1e-4)
    _close(sums, rs, 1e-4)
    _close(cnts, rc, 1e-6)
    # dead slots never win; padded points contribute nothing
    assert int(np.asarray(lab).max()) < k - dead or dead == 0
    assert float(np.asarray(cnts).sum()) == pytest.approx(n - pad)


@pytest.mark.parametrize("n,k,d,block_n", [
    (96, 4, 16, 32), (200, 6, 8, 128), (33, 3, 12, 64),
])
def test_silhouette_sums_parity(n, k, d, block_n):
    """Blocked silhouette accumulator vs the full-matrix oracle (the n x n
    distance matrix never materializes in the kernel)."""
    ks = jax.random.split(jax.random.PRNGKey(n), 2)
    x = jax.random.normal(ks[0], (n, d))
    lab = jax.random.randint(ks[1], (n,), 0, k)
    mask = jnp.where(jnp.arange(n) < n - 3, 1.0, 0.0)
    onehot = jax.nn.one_hot(lab, k) * mask[:, None]
    got = silhouette_sums(x, onehot, block_n=block_n, interpret=True)
    want = silhouette_sums_ref(x, onehot)
    _close(got, want, 1e-3)


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------

FLASH_ODD_SHAPES = [
    # (B, S, K, G, hd, bq, bk) — non-pow2 head dims, rectangular blocks,
    # single-block sequences
    (1, 96, 1, 3, 48, 32, 48),
    (2, 32, 2, 1, 24, 32, 32),   # S == block (single q and kv block)
    (1, 192, 3, 2, 8, 64, 96),   # tiny head dim, rect blocks
]


@pytest.mark.parametrize("B,S,K,G,hd,bq,bk", FLASH_ODD_SHAPES)
@pytest.mark.parametrize("dtype", [F32, BF16])
def test_flash_attention_parity_odd(B, S, K, G, hd, bq, bk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(30), 3)
    q = jax.random.normal(ks[0], (B, S, K, G, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, K, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, K, hd), dtype)
    out = flash_attention_fwd(q, k, v, scale=hd**-0.5, block_q=bq,
                              block_k=bk, interpret=True)
    ref = attention_ref(q, k, v, hd**-0.5)
    _close(out, ref, 1e-5 if dtype == F32 else 3e-2)


def test_flash_attention_single_query_row():
    """S = 1 degenerate: causal attention over one position is the value
    row itself."""
    ks = jax.random.split(jax.random.PRNGKey(31), 3)
    q = jax.random.normal(ks[0], (1, 1, 1, 1, 16))
    k = jax.random.normal(ks[1], (1, 1, 1, 16))
    v = jax.random.normal(ks[2], (1, 1, 1, 16))
    out = flash_attention_fwd(q, k, v, scale=0.25, block_q=1, block_k=1,
                              interpret=True)
    _close(out[0, 0, 0, 0], v[0, 0, 0], 1e-5)


# ---------------------------------------------------------------------------
# ssd_scan
# ---------------------------------------------------------------------------

SSD_ODD_SHAPES = [
    # (B, S, nh, hp, ds, Q)
    (1, 48, 3, 12, 6, 16),   # odd heads / non-pow2 head dim
    (2, 16, 1, 8, 4, 16),    # single chunk (S == Q)
    (1, 96, 5, 4, 12, 32),   # many small heads
]


@pytest.mark.parametrize("B,S,nh,hp,ds,Q", SSD_ODD_SHAPES)
@pytest.mark.parametrize("dtype", [F32, BF16])
def test_ssd_parity_odd(B, S, nh, hp, ds, Q, dtype):
    ks = jax.random.split(jax.random.PRNGKey(40), 5)
    x = (jax.random.normal(ks[0], (B, S, nh, hp)) * 0.5).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, nh))).astype(dtype)
    A = -jnp.exp(jax.random.normal(ks[2], (nh,)) * 0.3)
    Bc = (jax.random.normal(ks[3], (B, S, ds)) * 0.5).astype(dtype)
    Cc = (jax.random.normal(ks[4], (B, S, ds)) * 0.5).astype(dtype)
    y, final = ssd_scan(x, dt, A, Bc, Cc, Q, True)
    ys, fs = ssd_sequential_ref(
        x.astype(F32), dt.astype(F32), A, Bc.astype(F32), Cc.astype(F32))
    tol = 1e-3 if dtype == F32 else 4e-2
    _close(y, ys, tol)
    _close(final, fs, tol)
    assert y.dtype == dtype


def test_ssd_zero_input_is_zero():
    """x = 0 degenerate: state and output stay identically zero."""
    B, S, nh, hp, ds = 1, 32, 2, 8, 4
    ks = jax.random.split(jax.random.PRNGKey(41), 4)
    x = jnp.zeros((B, S, nh, hp))
    dt = jax.nn.softplus(jax.random.normal(ks[0], (B, S, nh)))
    A = -jnp.exp(jax.random.normal(ks[1], (nh,)) * 0.3)
    Bc = jax.random.normal(ks[2], (B, S, ds))
    Cc = jax.random.normal(ks[3], (B, S, ds))
    y, final = ssd_scan(x, dt, A, Bc, Cc, 16, True)
    _close(y, jnp.zeros_like(y), 1e-6)
    _close(final, jnp.zeros_like(final), 1e-6)
