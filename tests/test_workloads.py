"""Scenario workload subsystem: spec round-trip + determinism, every family
generates valid programs, streaming ingestion parity with bounded peak
residency, generated-program store keys (spec/seed in the fingerprint), and
the `--suite scenarios` grid path."""

import numpy as np
import pytest

from repro.core.batching import stream_bins
from repro.core.sampler import GCLSampler, GCLSamplerConfig
from repro.core.train import GCLTrainConfig
from repro.launch.sample import run_grid, validate_results
from repro.sampling import get_method, program_fingerprint
from repro.tracing.programs import Program, get_program
from repro.tracing.templates import make_kernel
from repro.workloads import (
    ScenarioSpec, build_scenario, is_scenario_name, iter_program_graphs,
    scenario_families, scenario_family_of, scenario_matrix, spec_from_name,
    stream_pack,
)

SMALL = dict(phases=2, phase_len=4)


# ---------------------------------------------------------------------------
# spec round-trip + generation determinism
# ---------------------------------------------------------------------------

def test_spec_name_round_trip():
    spec = ScenarioSpec("pipeline", seed=7, phases=4, phase_len=9, scale=1.5)
    back = spec_from_name(spec.name)
    assert back == spec
    assert spec_from_name("scn:iterative") == ScenarioSpec("iterative")
    assert is_scenario_name("scn:iterative") and not is_scenario_name("nw")


def test_spec_name_round_trip_is_exact_for_floats():
    """repr-based float serialization: name -> spec loses nothing, so
    build_scenario(spec) and get_program(spec.name) agree for ANY scale."""
    spec = ScenarioSpec("pipeline", scale=1.2345678901234567, skew=0.1 + 0.2)
    back = spec_from_name(spec.name)
    assert back == spec and back.content_hash() == spec.content_hash()


def test_spec_canonicalizes_field_types():
    """ScenarioSpec(scale=2) and ScenarioSpec(scale=2.0) are the SAME spec
    (equal, same name, same content hash)."""
    a, b = ScenarioSpec("mem_mix", scale=2), ScenarioSpec("mem_mix", scale=2.0)
    assert a == b and a.name == b.name
    assert a.content_hash() == b.content_hash()
    assert isinstance(a.scale, float) and isinstance(a.seed, int)


def test_spec_name_rejects_malformed():
    with pytest.raises(ValueError):
        spec_from_name("nw")
    with pytest.raises(ValueError):
        spec_from_name("scn:")
    with pytest.raises(ValueError):
        spec_from_name("scn:pipeline:bogus=1")
    with pytest.raises(ValueError):
        spec_from_name("scn:pipeline:family=x")


@pytest.mark.parametrize("family", [
    "iterative", "phase_shift", "mem_mix", "divergent", "pipeline",
    "long_tail",
])
def test_family_generates_deterministic_program(family):
    spec = ScenarioSpec(family, seed=3, **SMALL)
    a, b = build_scenario(spec), build_scenario(spec)
    assert len(a) > 0
    assert [k.name for k in a.kernels] == [k.name for k in b.kernels]
    assert [k.params for k in a.kernels] == [k.params for k in b.kernels]
    assert [k.seq for k in a.kernels] == list(range(len(a)))
    # every kernel traces + simulates (the two downstream consumers)
    tr = a.kernels[0].trace(1, 32)
    assert len(tr) >= 1 and len(tr[0].opcode) > 0
    assert a.kernels[0].stats("P1").warp_instructions > 0


def test_seeds_change_the_program():
    s0 = build_scenario(ScenarioSpec("mem_mix", seed=0, **SMALL))
    s1 = build_scenario(ScenarioSpec("mem_mix", seed=1, **SMALL))
    assert (
        [k.name for k in s0.kernels] != [k.name for k in s1.kernels]
        or [k.params for k in s0.kernels] != [k.params for k in s1.kernels]
    )


def test_scenario_matrix_and_get_program():
    names = scenario_matrix(["pipeline", "long_tail"], seeds=(0, 1),
                            **SMALL)
    assert len(names) == 4 and len(set(names)) == 4
    prog = get_program(names[0])
    assert prog.name == names[0] and len(prog) > 0
    # scn: programs are rebuilt per call (the open-ended name space is not
    # memoized) but deterministically identical
    again = get_program(names[0])
    assert [k.name for k in again.kernels] == [k.name for k in prog.kernels]
    assert scenario_family_of(names[0]) == "pipeline"
    assert scenario_family_of("nw") == "paper"
    assert set(scenario_families()) >= {
        "iterative", "phase_shift", "mem_mix", "divergent", "pipeline",
        "long_tail",
    }


# ---------------------------------------------------------------------------
# store keys: spec/seed must be part of the program fingerprint (regression)
# ---------------------------------------------------------------------------

def test_fingerprint_differs_across_seeds_same_names():
    """Two generated programs can share every kernel NAME while differing
    only in seed/spec — their artifacts must not collide in the store."""
    a = build_scenario(ScenarioSpec("pipeline", seed=0, **SMALL))
    b = build_scenario(ScenarioSpec("pipeline", seed=1, **SMALL))
    # the pipeline family reuses stage names across frames: same name list
    assert [k.name for k in a.kernels] == [k.name for k in b.kernels]
    assert program_fingerprint(a) != program_fingerprint(b)


def test_fingerprint_sees_params_and_seed_not_just_names():
    ka = [make_kernel("k", "gemm", {"M": 64, "N": 64, "K": 64}, 0, seed=1)]
    kb = [make_kernel("k", "gemm", {"M": 64, "N": 64, "K": 128}, 0, seed=1)]
    kc = [make_kernel("k", "gemm", {"M": 64, "N": 64, "K": 64}, 0, seed=2)]
    fa = program_fingerprint(Program("p", ka))
    assert fa != program_fingerprint(Program("p", kb))   # params differ
    assert fa != program_fingerprint(Program("p", kc))   # trace seed differs
    assert fa == program_fingerprint(Program("p", list(ka)))  # stable


def test_fingerprint_is_filesystem_safe():
    prog = build_scenario(ScenarioSpec("iterative", seed=2, **SMALL))
    fp = program_fingerprint(prog)
    assert "/" not in fp and ":" not in fp and "=" not in fp


def test_generated_programs_get_distinct_artifact_keys(tmp_path):
    from repro.sampling import ArtifactStore

    store = ArtifactStore(str(tmp_path))
    m = get_method("sieve")
    a = build_scenario(ScenarioSpec("pipeline", seed=0, **SMALL))
    b = build_scenario(ScenarioSpec("pipeline", seed=1, **SMALL))
    _, art_a = m.run(a, store=store)
    _, art_b = m.run(b, store=store)
    assert art_a.key != art_b.key
    assert store.has("sieve", art_a.key) and store.has("sieve", art_b.key)


# ---------------------------------------------------------------------------
# streaming ingestion: bounded residency + parity with the materialized path
# ---------------------------------------------------------------------------

def test_stream_bins_respects_budgets_and_tracks_peaks():
    sizes = [(10, 5), (20, 40), (5, 5), (100, 1), (1, 100), (30, 30)]
    stats: dict = {}
    bins = list(stream_bins(iter(sizes), lambda s: s, max_nodes=40,
                            max_edges=50, max_graphs=3, stats=stats))
    assert [s for b in bins for s in b] == sizes       # order preserved
    for b in bins:
        assert len(b) <= 3
        # budget invariant is on CLAMPED sizes (oversized items are
        # truncated downstream by pack_graphs and always sit alone)
        assert sum(min(n, 40) for n, _ in b) <= 40
        assert sum(min(e, 50) for _, e in b) <= 50
        if any(n > 40 for n, _ in b) or any(e > 50 for _, e in b):
            assert len(b) == 1
    assert stats["bins"] == len(bins)
    assert stats["peak_resident_graphs"] <= 3
    # stats report TRUE residency: the (100, 1) / (1, 100) oversized items
    # show up unclamped
    assert stats["peak_resident_nodes"] == 100
    assert stats["peak_resident_edges"] == 100


def test_stream_bins_peaks_within_budget_for_small_items():
    """When no single item exceeds a budget, true residency IS bounded by
    one bin's budget — the memory guarantee the streaming path advertises."""
    sizes = [(10, 12), (20, 8), (5, 30), (30, 10), (15, 15)] * 4
    stats: dict = {}
    bins = list(stream_bins(iter(sizes), lambda s: s, max_nodes=40,
                            max_edges=50, max_graphs=3, stats=stats))
    assert sum(len(b) for b in bins) == len(sizes)
    assert stats["peak_resident_nodes"] <= 40
    assert stats["peak_resident_edges"] <= 50
    assert stats["peak_resident_graphs"] <= 3


def test_stream_pack_peak_residency_bounded_by_one_bucket():
    """The acceptance-criterion assertion: streaming a whole scenario
    program through pack_graphs never holds more than one micro-batch
    budget's worth of graphs."""
    prog = build_scenario(ScenarioSpec("long_tail", seed=0, phases=3,
                                       phase_len=8))
    max_nodes, max_edges, max_graphs = 2048, 4096, 16
    stats: dict = {}
    seen = 0
    for batch, meta, graphs in stream_pack(
            iter_program_graphs(prog, 1, 32), max_nodes=max_nodes,
            max_edges=max_edges, max_graphs=max_graphs, stats=stats):
        seen += meta.n_graphs
        assert meta.n_graphs <= max_graphs
        assert batch["node_mask"].sum() <= max_nodes
    assert seen == len(prog)
    assert 0 < stats["peak_resident_graphs"] <= max_graphs
    assert stats["peak_resident_nodes"] <= max_nodes
    # the stream never materialized the whole population at once
    assert stats["peak_resident_graphs"] < len(prog)


def _tiny_sampler():
    return GCLSampler(GCLSamplerConfig(
        cap_warps=1, cap_instr=32,
        train=GCLTrainConfig(steps=4, batch_size=4)))


def test_embed_stream_matches_materialized_embed():
    prog = build_scenario(ScenarioSpec("long_tail", seed=1, **SMALL))
    s = _tiny_sampler()
    graphs = s.build_graphs(prog)
    s.train(graphs)
    dense = s.embed(graphs)
    s.trainer._embed_cache.clear()
    stream = s.embed_stream(s.iter_graphs(prog))
    assert stream.shape == dense.shape
    np.testing.assert_allclose(stream, dense, atol=1e-5)
    st = s.trainer.embed_stats
    assert st["streaming"] and st["graphs"] == len(prog)
    assert st["peak_resident_graphs"] < max(len(prog), 2)


def test_embed_stream_requires_trained_encoder():
    s = _tiny_sampler()
    with pytest.raises(RuntimeError, match="train"):
        s.embed_stream(iter([]))


def test_gcl_method_streaming_plan_matches_materialized():
    prog = build_scenario(ScenarioSpec("pipeline", seed=0, **SMALL))
    kw = dict(steps=4, batch_size=4, cap_instr=32)
    plan_s, art_s = get_method("gcl", streaming=True, **kw).run(prog)
    plan_m, art_m = get_method("gcl", streaming=False, **kw).run(prog)
    np.testing.assert_array_equal(plan_s.labels, plan_m.labels)
    assert plan_s.reps == plan_m.reps
    assert art_s.meta["streaming"] and not art_m.meta["streaming"]
    assert "peak_resident_graphs" in art_s.meta["embed"]
    assert art_s.key != art_m.key  # streaming is part of the config hash


# ---------------------------------------------------------------------------
# the scenarios suite through the grid CLI path
# ---------------------------------------------------------------------------

def test_run_grid_scenarios_suite(tmp_path):
    programs = scenario_matrix(["iterative", "mem_mix", "long_tail"],
                               seeds=(0,), **SMALL)
    doc = run_grid(["pka", "sieve"], programs, ["P1"], str(tmp_path),
                   suite="scenarios", verbose=False)
    validate_results(doc)
    assert not doc["failures"]
    assert len(doc["results"]) == 6  # 2 methods x 3 scenarios x 1 platform
    assert {r["family"] for r in doc["results"]} == {
        "iterative", "mem_mix", "long_tail"}
    assert doc["grid"]["suite"] == "scenarios"
    fams = {(s["method_id"], s["family"]) for s in doc["family_summary"]}
    assert len(fams) == 6
    for s in doc["family_summary"]:
        assert s["cells"] == 1 and s["geomean_speedup"] > 0


def test_split_programs_keeps_multi_field_scenario_names_intact():
    from repro.launch.sample import split_programs

    assert split_programs("nw,3mm") == ["nw", "3mm"]
    assert split_programs("scn:long_tail:seed=3,phase_len=24") == \
        ["scn:long_tail:seed=3,phase_len=24"]
    assert split_programs("nw,scn:iterative:phases=2,phase_len=6,3mm") == \
        ["nw", "scn:iterative:phases=2,phase_len=6", "3mm"]
    assert split_programs("scn:pipeline,scn:mem_mix:seed=1,scale=2.0") == \
        ["scn:pipeline", "scn:mem_mix:seed=1,scale=2.0"]


def test_validate_results_rejects_missing_family(tmp_path):
    doc = run_grid(["sieve"], ["3mm"], ["P1"], str(tmp_path), verbose=False)
    validate_results(doc)
    assert doc["results"][0]["family"] == "paper"
    import copy

    bad = copy.deepcopy(doc)
    del bad["results"][0]["family"]
    with pytest.raises(ValueError, match="family"):
        validate_results(bad)
    bad = copy.deepcopy(doc)
    bad["grid"]["suite"] = "bogus"
    with pytest.raises(ValueError, match="suite"):
        validate_results(bad)
    bad = copy.deepcopy(doc)
    del bad["family_summary"]
    with pytest.raises(ValueError, match="family_summary"):
        validate_results(bad)
