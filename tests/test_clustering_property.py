"""Property-based tests for K-Means / silhouette / K-selection."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")  # dev-only dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st

from repro.core.clustering import (
    kmeans, select_k_and_cluster, silhouette, _pairwise_sq,
)


@settings(max_examples=15, deadline=None)
@given(st.integers(10, 60), st.integers(2, 5), st.integers(0, 1000))
def test_kmeans_assigns_nearest_centroid(n, k, seed):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((n, 8)).astype(np.float32)
    labels, cent, inertia = kmeans(x, k, seed=seed)
    d = np.linalg.norm(x[:, None] - cent[None], axis=-1)
    np.testing.assert_array_equal(labels, d.argmin(1))
    assert inertia >= 0


@settings(max_examples=15, deadline=None)
@given(st.integers(8, 40), st.integers(0, 1000))
def test_silhouette_bounds(n, seed):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((n, 4)).astype(np.float32)
    labels = rng.integers(0, 3, n)
    if labels.max() == labels.min():
        labels[0] = (labels[0] + 1) % 3
    _, labels = np.unique(labels, return_inverse=True)
    s = silhouette(x, labels)
    assert -1.0 - 1e-6 <= s <= 1.0 + 1e-6


@settings(max_examples=10, deadline=None)
@given(st.integers(2, 5), st.integers(0, 500))
def test_k_selection_recovers_separated_blobs(k_true, seed):
    """Well-separated blobs -> silhouette picks the true K."""
    rng = np.random.default_rng(seed)
    centers = rng.standard_normal((k_true, 16)) * 50.0
    x = np.concatenate(
        [c + rng.standard_normal((20, 16)) * 0.5 for c in centers]
    ).astype(np.float32)
    labels, info = select_k_and_cluster(x, k_max=8, seed=0)
    assert info["k"] == k_true
    # perfect clustering up to relabeling
    true = np.repeat(np.arange(k_true), 20)
    for c in range(k_true):
        assert len(np.unique(labels[true == c])) == 1


def test_degenerate_points_collapse_to_one_cluster():
    x = np.ones((50, 8), np.float32)
    labels, info = select_k_and_cluster(x, seed=0)
    assert info["k"] == 1


def test_tiny_n_threshold_fallback():
    x = np.array([[0.0, 0.0], [0.01, 0.0], [10.0, 10.0]], np.float32)
    labels, info = select_k_and_cluster(x)
    assert info["k"] == 2
    assert labels[0] == labels[1] != labels[2]


def test_pairwise_sq_correct():
    rng = np.random.default_rng(0)
    x = rng.standard_normal((10, 3)).astype(np.float32)
    c = rng.standard_normal((4, 3)).astype(np.float32)
    d = np.asarray(_pairwise_sq(x, c))
    ref = ((x[:, None] - c[None]) ** 2).sum(-1)
    np.testing.assert_allclose(d, ref, atol=1e-4)
