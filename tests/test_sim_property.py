"""Timing-model properties (hypothesis) + error/speedup formula checks."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")  # dev-only dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st

from repro.sim.hardware import P1, P2, P3
from repro.sim.simulate import SamplingPlan, sampling_error, speedup
from repro.sim.timing import simulate_kernel
from repro.tracing.templates import make_kernel


def _stats(n=1 << 22, **kw):
    k = make_kernel("k", "elementwise", {"n": n, **kw}, 0, 0)
    return k.stats("P1")


@settings(max_examples=20, deadline=None)
@given(st.integers(18, 26))
def test_more_work_never_faster(log_n):
    s1 = _stats(1 << log_n)
    s2 = _stats(1 << (log_n + 1))
    m1 = simulate_kernel(s1, P1)
    m2 = simulate_kernel(s2, P1)
    assert m2.cycles >= m1.cycles


def test_metrics_in_range():
    for tmpl, params in [
        ("gemm", {"M": 1024, "N": 1024, "K": 1024}),
        ("traversal", {"nodes": 1 << 20, "degree": 8}),
        ("softmax", {"rows": 4096, "cols": 1024}),
    ]:
        st_ = make_kernel("k", tmpl, params, 0, 0).stats("P1")
        for hw in (P1, P2, P3):
            m = simulate_kernel(st_, hw)
            assert 0 <= m.l1_hit <= 1 and 0 <= m.l2_hit <= 1
            assert 0 < m.occupancy <= 1
            assert m.cycles > 0 and m.ipc > 0


def test_newer_hardware_not_slower():
    """P3 (Ada) >= P1 (Turing) on throughput workloads."""
    for tmpl, params in [
        ("gemm", {"M": 2048, "N": 2048, "K": 2048}),
        ("elementwise", {"n": 1 << 24}),
    ]:
        st_ = make_kernel("k", tmpl, params, 0, 0).stats
        t1 = simulate_kernel(st_("P1"), P1).time_s
        t3 = simulate_kernel(st_("P3"), P3).time_s
        assert t3 <= t1 * 1.05


def test_bigger_l2_higher_hit():
    # P2 and P3 share the L1 size, isolating the L2-capacity effect;
    # working set (~80MB) sits between the two L2 sizes (6MB / 72MB)
    st_ = make_kernel("k", "stencil",
                      {"nx": 16384, "ny": 1024, "pts": 5, "reuse": 4.0},
                      0, 0).stats("P2")
    m2 = simulate_kernel(st_, P2)  # 6MB L2
    m3 = simulate_kernel(st_, P3)  # 72MB L2
    assert m3.l2_hit >= m2.l2_hit


def test_error_formula():
    """eq.5: perfect plan -> 0; representative = half cycles -> 50%."""

    class M:
        def __init__(self, c):
            self.cycles = c
            self.time_s = c
            self.ipc = self.l1_hit = self.l2_hit = self.occupancy = 0.5

    metrics = [M(100.0), M(300.0)]
    plan = SamplingPlan(labels=np.array([0, 0]), reps={0: [0]})
    assert sampling_error(plan, metrics) == pytest.approx(50.0)
    plan2 = SamplingPlan(labels=np.array([0, 1]), reps={0: [0], 1: [1]})
    assert sampling_error(plan2, metrics) == pytest.approx(0.0)


def test_speedup_formula():
    class M:
        def __init__(self, t):
            self.time_s = t
            self.cycles = t

    metrics = [M(1.0)] * 10
    plan = SamplingPlan(labels=np.zeros(10, int), reps={0: [0]})
    assert speedup(plan, metrics) == pytest.approx(10.0)


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 30), st.integers(0, 100))
def test_multi_rep_reconstruction_bounded(n, seed):
    """Reconstruction with all kernels as reps of one cluster == exact mean."""
    rng = np.random.default_rng(seed)

    class M:
        def __init__(self, c):
            self.cycles = float(c)
            self.time_s = float(c)
            self.ipc = self.l1_hit = self.l2_hit = self.occupancy = 0.5

    metrics = [M(c) for c in rng.uniform(1, 100, n)]
    plan = SamplingPlan(labels=np.zeros(n, int), reps={0: list(range(n))})
    assert sampling_error(plan, metrics) < 1e-9
