"""Property-based tests for the generalized `plan_from_labels` policies:
across random label vectors, seqs, priorities, and rep selectors —
every cluster gets >=1 representative, reconstruction weights are
non-negative and sum to the program's invocation total, and multi-rep
plans never select out-of-range indices."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")  # dev-only dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st

from repro.sampling import plan_from_labels

# a random labeling problem: n invocations, labels in [0, k)
labelings = st.integers(2, 60).flatmap(
    lambda n: st.tuples(
        st.just(n),
        st.lists(st.integers(0, 7), min_size=n, max_size=n),
        st.integers(0, 10_000),
    )
)


def _setup(n, raw_labels, seed):
    rng = np.random.default_rng(seed)
    labels = np.asarray(raw_labels)
    seqs = rng.permutation(n)
    return labels, seqs, rng


def _check_reps_valid(plan, labels):
    n = len(labels)
    clusters = set(np.unique(labels).tolist())
    assert set(plan.reps) == clusters
    for c, reps in plan.reps.items():
        assert len(reps) >= 1, f"cluster {c} got no representative"
        members = set(np.nonzero(labels == c)[0].tolist())
        assert set(reps) <= members, "rep outside its own cluster"
        for r in reps:
            assert 0 <= r < n, "rep index out of range"
        assert reps == sorted(set(reps)), "reps must be sorted + unique"


def _check_weights(plan, labels):
    """Reconstruction weights (cluster count split across its reps) are
    non-negative and total the program's invocation count."""
    total = 0.0
    for c, reps in plan.reps.items():
        count = int(np.sum(labels == c))
        share = count / len(reps)
        assert share >= 0
        total += share * len(reps)
    assert total == pytest.approx(len(labels))


@settings(max_examples=40, deadline=None)
@given(labelings)
def test_default_policy_invariants(case):
    n, raw, seed = case
    labels, seqs, _ = _setup(n, raw, seed)
    plan = plan_from_labels(labels, seqs, "m")
    _check_reps_valid(plan, labels)
    _check_weights(plan, labels)
    for c, (rep,) in plan.reps.items():
        members = np.nonzero(labels == c)[0]
        assert seqs[rep] == seqs[members].min(), \
            "default rep must be the first invocation (min seq)"


@settings(max_examples=40, deadline=None)
@given(labelings)
def test_priority_policy_invariants(case):
    n, raw, seed = case
    labels, seqs, rng = _setup(n, raw, seed)
    priority = rng.integers(0, 5, size=n)
    plan = plan_from_labels(labels, seqs, "m", priority=priority)
    _check_reps_valid(plan, labels)
    _check_weights(plan, labels)
    for c, (rep,) in plan.reps.items():
        members = np.nonzero(labels == c)[0]
        pmax = priority[members].max()
        assert priority[rep] == pmax, "rep must attain the max priority"
        best = members[priority[members] == pmax]
        assert seqs[rep] == seqs[best].min(), "min seq breaks priority ties"


@settings(max_examples=40, deadline=None)
@given(labelings, st.integers(1, 4))
def test_multi_rep_selector_invariants(case, n_reps):
    n, raw, seed = case
    labels, seqs, rng = _setup(n, raw, seed)

    def selector(cluster, members):
        take = min(n_reps, len(members))
        return rng.choice(members, size=take, replace=False)

    plan = plan_from_labels(labels, seqs, "m", rep_selector=selector)
    _check_reps_valid(plan, labels)
    _check_weights(plan, labels)
    for c, reps in plan.reps.items():
        members = np.nonzero(labels == c)[0]
        assert len(reps) == min(n_reps, len(members))


@settings(max_examples=20, deadline=None)
@given(labelings)
def test_selector_duplicates_are_deduped(case):
    """A selector returning the same index twice must not double-count it
    (reps are a set; weights split over DISTINCT reps)."""
    n, raw, seed = case
    labels, seqs, _ = _setup(n, raw, seed)
    plan = plan_from_labels(
        labels, seqs, "m",
        rep_selector=lambda c, members: [members[0], members[0]])
    _check_reps_valid(plan, labels)
    _check_weights(plan, labels)
    for reps in plan.reps.values():
        assert len(reps) == 1
