"""Golden regression for the evaluation pipeline: the checked-in
tests/fixtures/golden_results.json was produced by the grid CLI
(2 programs x 2 deterministic methods x P1).  Re-running the grid must
reproduce it — schema AND values — within numeric tolerance, so the eq. 5
error / eq. 6 speedup math, the reconstruction weighting, and the timing
model cannot drift silently.

Regenerate the fixture (ONLY after an intentional change to the math):

    PYTHONPATH=src python - <<'EOF'
    import json, tempfile
    from repro.launch.sample import run_grid
    doc = run_grid(["pka", "sieve"], ["3mm", "backprop"], ["P1"],
                   tempfile.mkdtemp(), verbose=False)
    with open("tests/fixtures/golden_results.json", "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
    EOF
"""

import json
import os

import pytest

from repro.launch.sample import run_grid, validate_results

FIXTURE = os.path.join(os.path.dirname(__file__), "fixtures",
                       "golden_results.json")
# wall-clock / environment-dependent fields, not part of the golden contract
IGNORE_KEYS = {"created_unix", "wall_time_s", "fit_s", "timings",
               "batch_plan_errors"}  # diagnostics, not golden numerics
RTOL = 1e-6


def _strip(obj):
    if isinstance(obj, dict):
        return {k: _strip(v) for k, v in sorted(obj.items())
                if k not in IGNORE_KEYS}
    if isinstance(obj, list):
        return [_strip(v) for v in obj]
    return obj


def _assert_same(got, want, path="$"):
    if isinstance(want, dict):
        assert isinstance(got, dict), f"{path}: {type(got)} != dict"
        assert set(got) == set(want), (
            f"{path}: keys differ: +{set(got) - set(want)} "
            f"-{set(want) - set(got)}")
        for k in want:
            _assert_same(got[k], want[k], f"{path}.{k}")
    elif isinstance(want, list):
        assert isinstance(got, list) and len(got) == len(want), \
            f"{path}: length {len(got)} != {len(want)}"
        for i, (g, w) in enumerate(zip(got, want)):
            _assert_same(g, w, f"{path}[{i}]")
    elif isinstance(want, bool) or not isinstance(want, (int, float)):
        assert got == want, f"{path}: {got!r} != {want!r}"
    else:  # numeric: tolerance comparison
        assert got == pytest.approx(want, rel=RTOL, abs=1e-9), \
            f"{path}: {got} != {want}"


@pytest.fixture(scope="module")
def golden():
    with open(FIXTURE) as f:
        return json.load(f)


def test_fixture_is_schema_valid(golden):
    validate_results(golden)
    assert not golden["failures"]
    assert len(golden["results"]) == 4  # 2 methods x 2 programs x 1 platform


def test_grid_reproduces_golden_results(tmp_path, golden):
    doc = run_grid(golden["grid"]["methods"], golden["grid"]["programs"],
                   golden["grid"]["platforms"], str(tmp_path), verbose=False)
    validate_results(doc)
    _assert_same(_strip(doc), _strip(golden))


def test_golden_pins_the_paper_structure(golden):
    """Sanity anchors: the fixture itself must encode the behaviors the
    programs were designed to show (so a silently-regenerated fixture that
    lost them would be caught in review)."""
    rows = {(r["method_id"], r["program"]): r for r in golden["results"]}
    # backprop: 2 singleton kernels with identical PKA features -> merged
    assert rows[("pka", "backprop")]["num_clusters"] == 1
    assert rows[("pka", "backprop")]["error_pct"]["cycles"] > 10.0
    # sieve keys on names: distinct names -> every kernel its own stratum,
    # zero error, no speedup
    assert rows[("sieve", "3mm")]["num_reps"] == 9
    assert rows[("sieve", "3mm")]["error_pct"]["cycles"] == pytest.approx(0.0)
    assert rows[("sieve", "3mm")]["speedup"] == pytest.approx(1.0)
